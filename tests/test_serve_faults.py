"""Fault injection + recovery: the serving stack under seeded failures.

PR 9's load-bearing guarantee: every recovery path is *provable* because
the fault plan is deterministic — the same seeded plan yields the same
quarantine set, the same retry outcomes, the same restored tokens.  The
engine-level tests all follow one shape: run a scripted workload clean,
run it again under a ``FaultPlan``, and assert that (a) exactly the
targeted requests are quarantined with a diagnostic, (b) every *healthy*
request's token stream is array-equal to the clean run (batch rows are
independent; a poisoned neighbor must not perturb them), and (c) where a
recovery exists (retry, swap restore, kernel degradation, watchdog
snapshot restore) the recovered stream is token-identical too.

Coverage by site:

* ``prefill_nan``  — quarantined at admission, healthy slots stream on;
                     an engine-level retry replays token-identically.
* ``page_corrupt`` — mid-decode scale-marker corruption caught by the
                     next window's poison scan.
* ``alloc_fail``   — page-grant failure degrades to preempt-and-swap
                     (token-identical resume), never a crash.
* ``swap_corrupt`` — corrupted host payload detected *after* restore by
                     the first post-restore health scan.
* ``kernel_fail``  — Pallas launch failure demotes paged attention to
                     the dense fallback (logged once, tokens unchanged).
* ``stall``        — a hung step is cut short by the front-end watchdog
                     and replayed from the last snapshot.

Unit tests cover ``FaultPlan`` parse/counting/rid-target semantics, the
pool corruption/scrub helpers, and the trace loader's timestamp
validation (satellite: reject, never silently repair).
"""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import SCALE_NAN
from repro.kernels import backend
from repro.models import Model, load_reduced
from repro.models.config import QuantPolicy
from repro.serve import (AsyncServer, ContinuousBatchingEngine, Fault,
                         FaultPlan, GenerationConfig, RetriesExhausted,
                         load_trace, save_trace)
from repro.serve.faults import (corrupt_swap_payload, poison_pool_pages,
                                scrub_pool_pages)
from repro.serve.traffic import Arrival

MIXED = QuantPolicy.parse("kv_key=int8@32:paper,kv_value=e4m3@32:paper")
PAGE = 8
NEW = 10


@pytest.fixture(autouse=True)
def _clean_backend():
    """Kernel degradation is process-global state; isolate every test."""
    backend.reset_degradation()
    yield
    backend.reset_degradation()


@pytest.fixture(scope="module")
def mixed():
    cfg = load_reduced("chatglm3_6b", mx=MIXED)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, lens=(7, 12, 9), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
            for n in lens]


def _engine(model, params, faults=None, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 40)
    kw.setdefault("sync_every", 4)
    kw.setdefault("gen", GenerationConfig(max_new_tokens=NEW))
    return ContinuousBatchingEngine(model, params, page_size=PAGE,
                                    faults=faults, **kw)


def _failed_rids(eng):
    return {r.rid: r.error for r in eng.scheduler.failed}


# =============================================================================
# FaultPlan: parse / counting / rid-target semantics
# =============================================================================
def test_fault_plan_parse():
    plan = FaultPlan.parse("prefill_nan:rid=2,page_corrupt:nth=1,"
                           "stall:stall_s=0.5,kernel_fail:always", seed=7)
    assert plan.seed == 7
    assert [f.site for f in plan.faults] == [
        "prefill_nan", "page_corrupt", "stall", "kernel_fail"]
    assert plan.faults[0].rid == 2 and plan.faults[0].nth == 0
    assert plan.faults[1].nth == 1 and plan.faults[1].rid is None
    assert plan.faults[2].stall_s == 0.5
    assert plan.faults[3].always


def test_fault_plan_parse_rejects_bad_input():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan.parse("page_corupt")
    with pytest.raises(ValueError, match="bad fault modifier"):
        FaultPlan.parse("stall:speed=9")
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan([]).should_fire("nope")


def test_fault_plan_nth_counts_consultations():
    plan = FaultPlan([Fault("stall", nth=2)])
    assert [plan.should_fire("stall") is not None
            for _ in range(5)] == [False, False, True, False, False]
    assert plan.fired == [("stall", None, 2)]


def test_fault_plan_always_fires_every_time():
    plan = FaultPlan([Fault("kernel_fail", always=True)])
    assert all(plan.should_fire("kernel_fail") is not None
               for _ in range(3))
    assert len(plan.fired) == 3


def test_fault_plan_rid_target_semantics():
    """A fault's rid filters rid-scoped consultations (per-rid count);
    at a site-wide consultation it is a *target hint* the caller reads
    off the returned fault, matched against the site-wide count."""
    plan = FaultPlan([Fault("prefill_nan", rid=7, nth=1)])
    assert plan.should_fire("prefill_nan", rid=3) is None   # wrong rid
    assert plan.should_fire("prefill_nan", rid=7) is None   # rid count 0
    f = plan.should_fire("prefill_nan", rid=7)              # rid count 1
    assert f is not None and f.rid == 7
    # site-wide consultations use the site-wide count
    plan2 = FaultPlan([Fault("page_corrupt", rid=7, nth=1)])
    assert plan2.should_fire("page_corrupt") is None        # site count 0
    assert plan2.should_fire("page_corrupt") is not None    # site count 1


def test_fault_plan_rng_is_deterministic():
    a, b = FaultPlan(seed=5), FaultPlan(seed=5)
    a.should_fire("page_corrupt"), b.should_fire("page_corrupt")
    assert (a.rng("page_corrupt").integers(1 << 30)
            == b.rng("page_corrupt").integers(1 << 30))
    c = FaultPlan(seed=6)
    c.should_fire("page_corrupt")
    assert (a.rng("page_corrupt").integers(1 << 30)
            != c.rng("page_corrupt").integers(1 << 30))


# =============================================================================
# Corruption / scrub helpers over pool pytrees
# =============================================================================
def _fake_pool():
    return {"layers": {
        "ks_pages": jnp.zeros((6, 4, 2, 3), jnp.uint8),
        "k_pages": jnp.ones((2, 6, 4, 2, 3), jnp.float32),
    }}


def test_poison_then_scrub_roundtrip():
    pool = poison_pool_pages(_fake_pool(), [1, 4])
    ks = np.asarray(pool["layers"]["ks_pages"])
    kf = np.asarray(pool["layers"]["k_pages"])
    assert (ks[[1, 4]] == SCALE_NAN).all() and not ks[[0, 2, 3, 5]].any()
    assert np.isnan(kf[:, [1, 4]]).all()         # stacked rank hit too
    assert np.isfinite(kf[:, [0, 2, 3, 5]]).all()

    pool = scrub_pool_pages(pool, [1, 4])
    assert not np.asarray(pool["layers"]["ks_pages"]).any()
    assert not np.asarray(pool["layers"]["k_pages"])[:, [1, 4]].any()
    # pages never poisoned keep their payload
    assert (np.asarray(pool["layers"]["k_pages"])[:, [0, 2]] == 1).all()


def test_poison_single_offset_hits_one_token():
    pool = poison_pool_pages(_fake_pool(), [2], offset=3)
    ks = np.asarray(pool["layers"]["ks_pages"])
    assert (ks[2, 3] == SCALE_NAN).all() and ks[2, :3].sum() == 0


def test_corrupt_swap_payload_replaces_readonly_views():
    dev = _fake_pool()["layers"]
    host = {"layers": {k: np.asarray(v) for k, v in dev.items()}}
    for v in host["layers"].values():
        v.setflags(write=False)          # gather_pages returns r/o views
    assert corrupt_swap_payload(host) == 2
    assert (host["layers"]["ks_pages"] == SCALE_NAN).all()
    assert np.isnan(host["layers"]["k_pages"]).all()


# =============================================================================
# Trace loader: validate timestamps, never silently repair
# =============================================================================
def _write_trace(path, ts):
    arr = [Arrival(t=t, prompt=np.asarray([1, 2], np.int32),
                   max_new_tokens=2) for t in ts]
    with open(path, "w") as f:
        for a in arr:
            f.write('{"t": %r, "prompt": [1, 2], "max_new_tokens": 2}\n'
                    % a.t)
    return str(path)


def test_load_trace_rejects_negative_time(tmp_path):
    p = _write_trace(tmp_path / "t.jsonl", [0.0, -1.0])
    with pytest.raises(ValueError, match=r"t\.jsonl:2.*>= 0"):
        load_trace(p)


def test_load_trace_rejects_nonfinite_time(tmp_path):
    p = tmp_path / "t.jsonl"
    p.write_text('{"t": NaN, "prompt": [1], "max_new_tokens": 1}\n')
    with pytest.raises(ValueError, match=r"t\.jsonl:1.*finite"):
        load_trace(str(p))


def test_load_trace_rejects_nonmonotonic_time(tmp_path):
    p = _write_trace(tmp_path / "t.jsonl", [0.0, 2.0, 1.0])
    with pytest.raises(ValueError,
                       match=r"t\.jsonl:3.*non-monotonic.*line 2"):
        load_trace(p)


def test_load_trace_roundtrip_valid(tmp_path):
    p = tmp_path / "t.jsonl"
    save_trace(str(p), [Arrival(t=float(i), prompt=np.arange(1, 4,
                                dtype=np.int32), max_new_tokens=3)
                        for i in range(3)])
    got = load_trace(str(p))
    assert [a.t for a in got] == [0.0, 1.0, 2.0]


# =============================================================================
# Engine recovery, site by site
# =============================================================================
def _run_clean(mixed, **kw):
    cfg, model, params = mixed
    eng = _engine(model, params, **kw)
    rids = [eng.add_request(p, NEW) for p in _prompts(cfg)]
    return rids, eng.run()


def test_prefill_nan_quarantines_only_target(mixed):
    cfg, model, params = mixed
    rids, want = _run_clean(mixed)
    plan = FaultPlan.parse("prefill_nan:rid=1:always", seed=1)
    eng = _engine(model, params, faults=plan)
    got_rids = [eng.add_request(p, NEW) for p in _prompts(cfg)]
    out = eng.run()
    assert got_rids == rids
    failed = _failed_rids(eng)
    assert set(failed) == {1} and "prefill" in failed[1]
    assert eng.n_quarantined == 1
    assert 1 not in out
    for r in (0, 2):                     # healthy rows: token-identical
        np.testing.assert_array_equal(out[r], want[r])


def test_page_corrupt_quarantined_mid_decode(mixed):
    cfg, model, params = mixed
    rids, want = _run_clean(mixed)
    plan = FaultPlan.parse("page_corrupt:nth=2:rid=2", seed=2)
    eng = _engine(model, params, faults=plan)
    for p in _prompts(cfg):
        eng.add_request(p, NEW)
    out = eng.run()
    failed = _failed_rids(eng)
    # either guard may report first: the marker scale both trips the
    # poison scan and drives the same window's logits non-finite
    assert set(failed) == {2}
    assert "poison" in failed[2] or "non-finite logits" in failed[2]
    assert ("page_corrupt", None, 2) in plan.fired
    for r in (0, 1):
        np.testing.assert_array_equal(out[r], want[r])


def test_quarantined_request_retries_token_identical(mixed):
    """Same rid -> same per-slot PRNG key -> a clean replay after
    ``retry_request`` emits exactly the clean run's tokens."""
    cfg, model, params = mixed
    _, want = _run_clean(mixed)
    plan = FaultPlan([Fault("prefill_nan", rid=1, nth=0)], seed=3)
    eng = _engine(model, params, faults=plan)
    for p in _prompts(cfg):
        eng.add_request(p, NEW)
    out = eng.run()
    assert set(_failed_rids(eng)) == {1} and 1 not in out
    req = eng.scheduler.failed[0]
    eng.retry_request(req)               # second admission: rid count 1,
    out2 = eng.run()                     # fault stays quiet
    assert not eng.scheduler.failed and req.n_retries == 1
    np.testing.assert_array_equal(out2[1], want[1])
    for r in (0, 2):
        np.testing.assert_array_equal(out[r], want[r])


def test_alloc_fail_degrades_to_swap_out(mixed):
    """A failed page grant preempts the requesting slot instead of
    crashing; the swap restore resumes token-identically."""
    cfg, model, params = mixed
    rids, want = _run_clean(mixed, preempt=True)
    # nth counts non-trivial mid-decode page grants only (admission's
    # reserved allocations never consult the hook); this workload makes
    # roughly four such grants, so target the second one
    plan = FaultPlan.parse("alloc_fail:nth=1", seed=4)
    eng = _engine(model, params, faults=plan, preempt=True)
    for p in _prompts(cfg):
        eng.add_request(p, NEW)
    out = eng.run()
    assert not eng.scheduler.failed      # recovered, not quarantined
    assert eng.n_preemptions >= 1 and eng.n_restores == eng.n_preemptions
    assert plan.fired and plan.fired[0][0] == "alloc_fail"
    for r in rids:
        np.testing.assert_array_equal(out[r], want[r])


def test_swap_corrupt_detected_after_restore(mixed):
    """Corrupt the host payload at swap-out; the poison scan flags the
    victim at its first post-restore window, healthy requests are
    untouched."""
    cfg, model, params = mixed

    def drive(eng):
        rng = np.random.default_rng(3)
        victim = eng.add_request(
            rng.integers(1, cfg.vocab, size=9).astype(np.int32), 12,
            priority=5)
        eng.step()                       # victim is mid-generation
        others = [eng.add_request(
            rng.integers(1, cfg.vocab, size=17).astype(np.int32), 6,
            priority=0) for _ in range(2)]
        return victim, others, eng.run()

    v0, o0, want = drive(_engine(model, params, max_slots=2,
                                 preempt=True))
    plan = FaultPlan([Fault("swap_corrupt", rid=v0, always=True)], seed=5)
    eng = _engine(model, params, max_slots=2, preempt=True, faults=plan)
    v, o, out = drive(eng)
    assert (v, o) == (v0, o0)
    assert eng.n_preemptions >= 1        # the fault actually ran
    failed = _failed_rids(eng)
    assert set(failed) == {v}
    assert "poison" in failed[v] or "non-finite logits" in failed[v]
    for r in o:
        np.testing.assert_array_equal(out[r], want[r])


def test_kernel_fail_degrades_to_dense(caplog):
    """An injected Pallas launch failure mid-serve demotes paged
    attention to the dense path — logged once, token streams unchanged
    (the kernel and dense paths are bit-identical by construction)."""
    cfg = load_reduced("chatglm3_6b", mx=MIXED, attn_impl="flash")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = _engine(model, params)
    rids = [eng.add_request(p, NEW) for p in _prompts(cfg)]
    want = eng.run()
    assert not backend.is_degraded("paged_attn")     # kernel path ran

    plan = FaultPlan.parse("kernel_fail:nth=1", seed=6)
    eng = _engine(model, params, faults=plan)
    for p in _prompts(cfg):
        eng.add_request(p, NEW)
    with caplog.at_level("WARNING", logger="repro.kernels"):
        out = eng.run()
    assert backend.is_degraded("paged_attn")
    assert "injected" in backend.degraded_ops()["paged_attn"]
    assert sum("degrading" in r.message for r in caplog.records) == 1
    assert not eng.scheduler.failed
    for r in rids:
        np.testing.assert_array_equal(out[r], want[r])


def test_combined_plan_is_deterministic(mixed):
    """One plan exercising four sites at once: the healthy request is
    token-identical to the clean run, exactly the targeted requests are
    quarantined, and a replay of the same plan text fires identically."""
    cfg, model, params = mixed
    _, want = _run_clean(mixed)
    text = ("prefill_nan:rid=1:always,page_corrupt:nth=1:rid=2,"
            "kernel_fail:nth=0,stall:nth=0:stall_s=0.01")

    def run():
        plan = FaultPlan.parse(text, seed=9)
        eng = _engine(model, params, faults=plan)
        for p in _prompts(cfg):
            eng.add_request(p, NEW)
        out = eng.run()
        return plan, out, _failed_rids(eng)

    plan, out, failed = run()
    assert set(failed) == {1, 2}
    np.testing.assert_array_equal(out[0], want[0])
    sites = [s for s, _, _ in plan.fired]
    assert {"prefill_nan", "page_corrupt", "kernel_fail",
            "stall"} <= set(sites)

    backend.reset_degradation()
    plan2, out2, failed2 = run()
    assert plan2.fired == plan.fired and set(failed2) == set(failed)
    np.testing.assert_array_equal(out2[0], out[0])


# =============================================================================
# Async front end: retry budget, exhaustion, watchdog + snapshot restore
# =============================================================================
async def _serve(eng, prompts, **kw):
    out, errs = {}, {}
    async with AsyncServer(eng, **kw) as srv:
        streams = [await srv.submit(p, NEW) for p in prompts]
        for i, st in enumerate(streams):
            try:
                out[i] = await st.tokens()
            except Exception as e:       # noqa: BLE001 — collected
                errs[i] = e
        return srv, out, errs


def test_async_retry_recovers_quarantine(mixed):
    cfg, model, params = mixed
    _, want = _run_clean(mixed)
    plan = FaultPlan([Fault("prefill_nan", rid=1, nth=0)], seed=11)
    srv, out, errs = asyncio.run(_serve(
        _engine(model, params, faults=plan), _prompts(cfg),
        retries=1, retry_backoff_s=0.01))
    assert not errs and srv.n_retried == 1 and srv.n_failed == 0
    for i in range(3):
        np.testing.assert_array_equal(out[i], want[i])


def test_async_retries_exhausted_surfaces_error(mixed):
    cfg, model, params = mixed
    _, want = _run_clean(mixed)
    plan = FaultPlan([Fault("prefill_nan", rid=1, always=True)], seed=12)
    srv, out, errs = asyncio.run(_serve(
        _engine(model, params, faults=plan), _prompts(cfg),
        retries=1, retry_backoff_s=0.01))
    assert set(errs) == {1} and isinstance(errs[1], RetriesExhausted)
    assert srv.n_retried == 1 and srv.n_failed == 1
    for i in (0, 2):
        np.testing.assert_array_equal(out[i], want[i])


def test_async_watchdog_recovers_stalled_step(mixed):
    """A hung step (120 s injected stall) is cut short by the watchdog,
    the engine restored from the last snapshot, and every stream still
    finishes token-identical to the clean run.  ``watchdog_s`` must
    dominate first-trace compile time or slow-but-healthy steps trip
    spurious (sound, token-identical, wasteful) recoveries."""
    cfg, model, params = mixed
    _, want = _run_clean(mixed)
    plan = FaultPlan.parse("stall:nth=2:stall_s=120", seed=13)
    srv, out, errs = asyncio.run(_serve(
        _engine(model, params, faults=plan), _prompts(cfg),
        use_executor=True, watchdog_s=20, snapshot_every=1))
    assert not errs and srv.n_recoveries >= 1
    for i in range(3):
        np.testing.assert_array_equal(out[i], want[i])
