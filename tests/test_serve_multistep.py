"""Token-identity of the fused device-resident decode loop vs per-step.

The load-bearing guarantee of the multi-step restructuring: fusing
``sync_every`` decode steps into one jitted ``lax.scan`` (device-side
sampling, per-slot PRNG keys, in-scan paged-cache writes, done-slot
masking) changes *nothing* about the tokens — ``sync_every=N`` is
token-for-token identical to the per-step loop (``sync_every=1``) for all
six MX element formats x both conversion modes, the mixed
INT8-keys/E2M1-values policy, the unquantized cache, the paged Pallas
kernel path, and sampled (temperature > 0) decoding.

Requests carry *different* generation budgets, so evictions stagger and
admissions land while other slots are mid-generation inside a scan window;
slots also exhaust their budget in the middle of a window (NEW values are
not multiples of SYNC) — exercising the done-masking + trash-page path.
"""
import jax
import numpy as np
import pytest

from repro.core.formats import ALL_FORMATS
from repro.models import Model, load_reduced
from repro.models.config import QuantPolicy, QuantSpec
from repro.serve import ContinuousBatchingEngine, GenerationConfig

MIXED = QuantPolicy.parse("kv_key=int8@32:ocp,kv_value=e2m1@32:ocp")

# >= 8 requests, mixed lengths (3 distinct values to bound jit retraces);
# per-request budgets differ so slots free at different times and
# admissions/evictions land inside other slots' scan windows
LENS = [4, 9, 14, 4, 9, 14, 9, 4]
NEWS = [3, 7, 5, 6, 4, 7, 3, 5]
PAGE = 8
SLOTS = 3          # < len(LENS): admission + eviction + slot reuse on path
SYNC = 4           # no NEWS value is a multiple: budgets die mid-window


def _prompts(vocab, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab, size=n).astype(np.int32) for n in LENS]


def _serve(cfg, sync_every, temperature=0.0, prefill_bucket=None):
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg.vocab)
    eng = ContinuousBatchingEngine(
        model, params, max_slots=SLOTS, page_size=PAGE,
        max_len=max(LENS) + max(NEWS) + 1,
        gen=GenerationConfig(max_new_tokens=max(NEWS),
                             temperature=temperature),
        sync_every=sync_every, prefill_bucket=prefill_bucket)
    rids = [eng.add_request(p, n) for p, n in zip(prompts, NEWS)]
    outs = eng.run()
    return [outs[r] for r in rids], eng


def _assert_identical(a, b):
    assert len(a) == len(b)
    for i, (x, y) in enumerate(zip(a, b)):
        np.testing.assert_array_equal(x, y, err_msg=f"request {i}")
        assert len(x) == NEWS[i]


@pytest.mark.parametrize("mode", ["ocp", "paper"])
@pytest.mark.parametrize("fmt", [f.name for f in ALL_FORMATS])
def test_fused_matches_per_step_all_formats(fmt, mode):
    """sync_every=4 == sync_every=1 token-for-token — all six MX formats x
    both conversion modes (uniform KV policies)."""
    kv = QuantSpec(fmt, mode)
    cfg = load_reduced("chatglm3_6b",
                       mx=QuantPolicy(kv_key=kv, kv_value=kv))
    fused, _ = _serve(cfg, sync_every=SYNC)
    stepwise, _ = _serve(cfg, sync_every=1)
    _assert_identical(fused, stepwise)


def test_fused_matches_per_step_mixed_roles():
    """INT8 keys + E2M1 values through the fused loop (per-role packed
    pools written inside the scan)."""
    cfg = load_reduced("chatglm3_6b", mx=MIXED)
    fused, _ = _serve(cfg, sync_every=SYNC)
    stepwise, _ = _serve(cfg, sync_every=1)
    _assert_identical(fused, stepwise)


def test_fused_matches_per_step_fp_cache():
    cfg = load_reduced("chatglm3_6b")
    fused, _ = _serve(cfg, sync_every=SYNC)
    stepwise, _ = _serve(cfg, sync_every=1)
    _assert_identical(fused, stepwise)


def test_fused_matches_per_step_flash_kernel():
    """attn_impl=flash: the paged Pallas kernel runs inside the scan body
    (scalar-prefetch block-table gather per fused step)."""
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse("kv=int8@32:ocp"),
                       attn_impl="flash")
    fused, _ = _serve(cfg, sync_every=SYNC)
    stepwise, _ = _serve(cfg, sync_every=1)
    _assert_identical(fused, stepwise)


def test_fused_matches_per_step_sampled():
    """temperature > 0: per-slot PRNG keys are folded from the request id
    and split once per decode step, so the sample stream is independent of
    how steps are grouped into windows."""
    cfg = load_reduced("chatglm3_6b")
    fused, _ = _serve(cfg, sync_every=SYNC, temperature=0.7)
    stepwise, _ = _serve(cfg, sync_every=1, temperature=0.7)
    _assert_identical(fused, stepwise)


def test_prefill_bucket_invariant():
    """A coarser prefill bucket changes batching/padding, not tokens:
    causal attention makes each request's last-prompt-position logits
    independent of the bucket padding, and excess bucket pages scatter to
    the trash page."""
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse("kv=int8@32:ocp"))
    base, _ = _serve(cfg, sync_every=SYNC)
    coarse, eng = _serve(cfg, sync_every=SYNC, prefill_bucket=16)
    assert eng.prefill_bucket == 16
    _assert_identical(base, coarse)


# =============================================================================
# engine accounting (no equivalence partner needed)
# =============================================================================
def test_window_amortizes_host_syncs():
    """Fused windows run >= 1 device step per host sync; at sync_every=4
    the host syncs strictly fewer times than the per-step engine."""
    cfg = load_reduced("chatglm3_6b")
    _, fused = _serve(cfg, sync_every=SYNC)
    _, stepwise = _serve(cfg, sync_every=1)
    assert fused.n_syncs < stepwise.n_syncs
    assert fused.n_syncs <= fused.n_steps
    assert stepwise.n_syncs == stepwise.n_steps
    # over-generated (masked) device steps exist but are bounded by one
    # window per sync point
    assert fused.n_steps < stepwise.n_steps + SYNC * fused.n_syncs


def test_device_block_table_cached():
    """The device block table re-uploads only when the host tables change:
    after a run the cached version matches, and an unchanged table returns
    the same device buffer."""
    cfg = load_reduced("chatglm3_6b")
    _, eng = _serve(cfg, sync_every=SYNC)
    bt1 = eng._device_tables()
    assert eng._bt_version == eng.blocks.version
    bt2 = eng._device_tables()
    assert bt1 is bt2
    v0 = eng.blocks.version
    assert eng.blocks.allocate(0, 1)
    assert eng.blocks.version > v0
    assert eng._device_tables() is not bt1

def test_phase_accounting_populated():
    cfg = load_reduced("chatglm3_6b")
    _, eng = _serve(cfg, sync_every=SYNC)
    assert eng.phase["prefill"] > 0.0
    assert eng.phase["decode"] > 0.0
    assert eng.phase["sync"] > 0.0
