"""End-to-end per-layer policy serving (`PolicyTable` through the zoo).

The load-bearing guarantees:

* a table assigning *different* KV specs to different layers serves
  token-identically between the dense-attention fallback and the paged
  Pallas kernel path — and both match solo contiguous-cache serving;
* each layer's page pool is sized by its own specs (half-size packed
  E2M1 pages next to INT8 pages in one engine);
* an all-layers-identical table collapses to the uniform ``QuantPolicy``
  it names, taking the identical (scanned) code path bit-for-bit.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import PolicyTable, QuantPolicy
from repro.models import Model, apply_policy_table, load_reduced
from repro.serve import (ContinuousBatchingEngine, GenerationConfig,
                         ServeEngine)

TABLE = PolicyTable("kv=int8@32:ocp", {1: "kv_key=e2m1@32:ocp,"
                                          "kv_value=e4m3@32:ocp"})
LENS = [4, 9, 14, 9, 4]
NEW = 4
PAGE = 8
SLOTS = 2          # < len(LENS): admission + eviction on the path


@pytest.fixture(scope="module")
def setup():
    cfg = apply_policy_table(load_reduced("chatglm3_6b"), TABLE)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=n).astype(np.int32)
               for n in LENS]
    return cfg, params, prompts


def _serve_paged(cfg, params, prompts):
    eng = ContinuousBatchingEngine(Model(cfg), params, max_slots=SLOTS,
                                   page_size=PAGE,
                                   max_len=max(LENS) + NEW + 1,
                                   gen=GenerationConfig(max_new_tokens=NEW))
    rids = [eng.add_request(p, NEW) for p in prompts]
    out = eng.run()
    return eng, [out[r] for r in rids]


def test_per_layer_table_dense_matches_flash_kernel(setup):
    """Different KV specs per layer: paged dense fallback == paged Pallas
    kernel path, token for token."""
    cfg, params, prompts = setup
    _, dense = _serve_paged(cfg, params, prompts)
    _, flash = _serve_paged(dataclasses.replace(cfg, attn_impl="flash"),
                            params, prompts)
    for d, f in zip(dense, flash):
        np.testing.assert_array_equal(d, f)


def test_per_layer_table_matches_solo_contiguous(setup):
    """Paged continuous serving under the table == each request served
    alone through the contiguous per-layer cache."""
    cfg, params, prompts = setup
    _, paged = _serve_paged(cfg, params, prompts)
    model = Model(cfg)
    solos = {}
    for p, got in zip(prompts, paged):
        n = p.shape[0]
        if n not in solos:
            solos[n] = ServeEngine(model, params, max_len=n + NEW + 2)
        ref = solos[n].generate({"tokens": np.asarray(p)[None, :]},
                                GenerationConfig(max_new_tokens=NEW))[0]
        np.testing.assert_array_equal(got, ref)


def test_per_layer_pool_sized_per_layer(setup):
    """Layer 0 (INT8) pages are twice the bytes of layer 1's packed E2M1
    key pages; value pools differ per their own specs too."""
    cfg, params, prompts = setup
    model = Model(cfg)
    pool = jax.eval_shape(lambda: model.init_paged_cache(8, PAGE))
    layers = pool["layers"]
    assert isinstance(layers, list) and len(layers) == 2
    assert layers[0]["kc_pages"].shape[-1] == 32        # int8: 1B/elem
    assert layers[1]["kc_pages"].shape[-1] == 16        # e2m1 packed
    assert layers[1]["vc_pages"].shape[-1] == 32        # e4m3: 1B/elem
    assert layers[0]["ks_pages"].shape == layers[1]["ks_pages"].shape


def test_engine_reports_per_layer_pool_bytes(setup):
    cfg, params, prompts = setup
    eng, _ = _serve_paged(cfg, params, prompts)
    uni = apply_policy_table(cfg, PolicyTable("kv=int8@32:ocp"))
    eng_uni, _ = _serve_paged(uni, params, prompts)
    # the mixed table stores strictly fewer pool bytes than uniform INT8
    assert 0 < eng.kv_pool_nbytes < eng_uni.kv_pool_nbytes


def test_identical_table_collapses_bit_identical(setup):
    """An all-layers-identical PolicyTable == the uniform QuantPolicy:
    same config object, same (scanned) code path, same tokens."""
    cfg, params, prompts = setup
    uniform_pol = QuantPolicy.parse("kv=int8@32:ocp")
    collapsed = apply_policy_table(
        load_reduced("chatglm3_6b"),
        PolicyTable(uniform_pol, {0: uniform_pol, 1: uniform_pol}))
    direct = load_reduced("chatglm3_6b", mx=uniform_pol)
    assert collapsed == direct and collapsed.mx_table is None
    _, a = _serve_paged(collapsed, params, prompts)
    _, b = _serve_paged(direct, params, prompts)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_mixed_quantized_and_fp_layers_serve(setup):
    """A table may leave some layers' caches unquantized: fp pages on
    layer 0 next to packed E2M1 pages on layer 1."""
    cfg, params, prompts = setup
    t = PolicyTable(QuantPolicy(), {1: "kv=e2m1@32:ocp"})
    mixed = apply_policy_table(load_reduced("chatglm3_6b"), t)
    model = Model(mixed)
    pool = jax.eval_shape(lambda: model.init_paged_cache(8, PAGE))
    assert "k_pages" in pool["layers"][0]          # fp pages
    assert "kc_pages" in pool["layers"][1]         # packed codes
    _, out = _serve_paged(mixed, params, prompts)
    _, flash = _serve_paged(dataclasses.replace(mixed, attn_impl="flash"),
                            params, prompts)
    for d, f in zip(out, flash):
        np.testing.assert_array_equal(d, f)
