"""Preempt-and-swap: token-identical continuation across the format zoo.

The load-bearing guarantee of PR 8's SLO scheduler: preempting a running
request (KV pages swapped to host with their MX codes still packed, slot
freed for a higher-priority admission) and later restoring it
page-for-page yields **exactly** the tokens an unpreempted run produces —
for all six MX element formats x both conversion modes, for mixed
per-role policies, for a per-layer ``PolicyTable``, with the prefix cache
on (trie pins/refcounts intact across swap-out), and under temperature
sampling (the per-slot PRNG key is part of the swapped state).

Every scenario runs the same deterministic script twice: once against a
page pool sized so the interactive arrival *must* evict the batch
request, once against a large pool where nothing is preempted — same
submission order, same rids, so the sampling keys match and the outputs
must be array-equal.

Unit tests close out the file: ``gather_pages``/``scatter_pages``/
``concat_snapshots`` round trips over both pool-leaf ranks,
``HostSwapStore`` accounting, and ``BlockManager.swap_out`` semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PolicyTable
from repro.core.formats import ALL_FORMATS
from repro.models import Model, apply_policy_table, load_reduced
from repro.models.config import QuantPolicy, QuantSpec
from repro.serve import (BlockManager, ContinuousBatchingEngine,
                         GenerationConfig, HostSwapStore, SwapData)
from repro.serve.paging import TRASH_PAGE
from repro.serve.swap import concat_snapshots, gather_pages, scatter_pages

MIXED = QuantPolicy.parse("kv_key=int8@32:ocp,kv_value=e2m1@32:ocp")
TABLE = PolicyTable("kv=int8@32:ocp", {1: "kv_key=e2m1@32:ocp,"
                                          "kv_value=e4m3@32:ocp"})
PAGE = 8
MAX_LEN = 30
B_NEW = 20          # batch request: 9-token prompt -> 4 reserved pages
A_NEW = 4           # interactive: 17-token prompt -> 3 reserved pages


def _force_preempt(cfg, *, temperature=0.0, prefix_cache=False,
                   warm=None):
    """Run the eviction script on a tight pool and on a large pool;
    assert the tight run preempted and both runs emitted identical
    tokens.  Returns the tight engine for extra assertions."""
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    pb = rng.integers(1, cfg.vocab, size=9).astype(np.int32)
    pa = rng.integers(1, cfg.vocab, size=17).astype(np.int32)
    if warm is not None:        # both prompts open with the warmed prefix
        pb[:len(warm)] = warm
        pa[:len(warm)] = warm

    def build(num_pages):
        eng = ContinuousBatchingEngine(
            model, params, max_slots=2, page_size=PAGE, max_len=MAX_LEN,
            num_pages=num_pages,
            gen=GenerationConfig(max_new_tokens=B_NEW,
                                 temperature=temperature),
            sync_every=4, prefix_cache=prefix_cache, preempt=True)
        if warm is not None:
            eng.add_request(warm, 1)
            eng.run()
            eng.reset_metrics()
        return eng

    def drive(eng):
        rb = eng.add_request(pb, B_NEW, priority=1)
        req_b = next(r for r in eng.scheduler.waiting if r.rid == rb)
        while len(req_b.out) < 5:        # batch request is mid-generation
            eng.step()
        ra = eng.add_request(pa, A_NEW, priority=0, deadline_s=1.0)
        out = eng.run()
        return rb, ra, out

    # tight: the interactive arrival cannot fit beside the batch run
    # (with the prefix trie warm, one page is pinned and both prompts
    # get a shared-page credit, so the pool shrinks to compensate)
    tight = build(num_pages=6)
    rb, ra, out = drive(tight)
    assert tight.n_preemptions >= 1, "scenario failed to force eviction"
    assert tight.n_restores == tight.n_preemptions
    assert tight.swap_store.bytes_in == tight.swap_store.bytes_out > 0
    assert len(tight.swap_store) == 0    # every swap-out was restored
    assert tight.phase["swap"] >= 0.0

    ref = build(num_pages=32)
    rb2, ra2, want = drive(ref)
    assert (rb2, ra2) == (rb, ra)        # rid-matched: same sampling keys
    assert ref.n_preemptions == 0
    assert len(out[rb]) == B_NEW and len(out[ra]) == A_NEW
    np.testing.assert_array_equal(out[rb], want[rb])
    np.testing.assert_array_equal(out[ra], want[ra])
    return tight


# =============================================================================
# token identity across the zoo
# =============================================================================
@pytest.mark.parametrize("mode", ["ocp", "paper"])
@pytest.mark.parametrize("fmt", [f.name for f in ALL_FORMATS])
def test_preempt_token_identity_all_formats(fmt, mode):
    kv = QuantSpec(fmt, mode)
    cfg = load_reduced("chatglm3_6b",
                       mx=QuantPolicy(kv_key=kv, kv_value=kv))
    _force_preempt(cfg)


def test_preempt_token_identity_fp_cache():
    """Dense (unquantized) pages swap byte-for-byte too."""
    _force_preempt(load_reduced("chatglm3_6b"))


def test_preempt_token_identity_mixed_roles():
    _force_preempt(load_reduced("chatglm3_6b", mx=MIXED))


def test_preempt_token_identity_policy_table():
    """Per-layer PolicyTable: per-layer pool leaves (different packed
    widths per layer) gather/scatter through the same swap path."""
    cfg = apply_policy_table(load_reduced("chatglm3_6b"), TABLE)
    _force_preempt(cfg)


def test_preempt_token_identity_sampled():
    """temperature > 0: the per-slot PRNG key is saved at swap-out and
    restored at re-admission, so the sampled continuation is identical."""
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse("kv=int8@32:ocp"))
    _force_preempt(cfg, temperature=0.7)


def test_preempt_with_prefix_cache_keeps_trie_intact():
    """Swap-out of a request holding shared trie pages must not corrupt
    the prefix cache: the pinned pages survive, later arrivals still hit,
    and the restored request's continuation is token-identical."""
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse("kv=int8@32:ocp"))
    model = Model(cfg)
    rng = np.random.default_rng(11)
    warm = rng.integers(1, cfg.vocab, size=PAGE).astype(np.int32)

    eng = _force_preempt(cfg, prefix_cache=True, warm=warm)
    assert eng.prefix.hits >= 2          # both scripted prompts matched
    bm = eng.blocks
    assert bm.free_pages + bm.live_pages == 5     # accounting intact
    hits_before = eng.prefix.hits
    tail = rng.integers(1, cfg.vocab, size=3).astype(np.int32)
    eng.add_request(np.concatenate([warm, tail]), 2)
    eng.run()
    assert eng.prefix.hits == hits_before + 1     # trie still serves


# =============================================================================
# gather/scatter/concat over pool pytrees
# =============================================================================
def _fake_pool():
    return {
        "kc_pages": jnp.arange(6 * 4 * 2 * 3, dtype=jnp.float32
                               ).reshape(6, 4, 2, 3),
        "stacked": jnp.arange(2 * 6 * 4 * 2 * 3, dtype=jnp.int32
                              ).reshape(2, 6, 4, 2, 3),
    }


def test_gather_scatter_roundtrip_both_ranks():
    """(P, ...) per-layer leaves and (n_scan, P, ...) layer-stacked
    leaves both move page-for-page, and the restore lands only on the
    target physical pages."""
    pool = _fake_pool()
    host, nbytes = gather_pages(pool, [2, 5])
    assert host["kc_pages"].shape == (2, 4, 2, 3)
    assert host["stacked"].shape == (2, 2, 4, 2, 3)
    assert isinstance(host["kc_pages"], np.ndarray)
    assert nbytes == host["kc_pages"].nbytes + host["stacked"].nbytes
    np.testing.assert_array_equal(host["kc_pages"],
                                  np.asarray(pool["kc_pages"])[[2, 5]])
    np.testing.assert_array_equal(host["stacked"],
                                  np.asarray(pool["stacked"])[:, [2, 5]])

    zero = jax.tree_util.tree_map(jnp.zeros_like, pool)
    new_ids = np.asarray([1, 3])
    out = scatter_pages(zero, new_ids, host)
    np.testing.assert_array_equal(np.asarray(out["kc_pages"])[new_ids],
                                  host["kc_pages"])
    np.testing.assert_array_equal(np.asarray(out["stacked"])[:, new_ids],
                                  host["stacked"])
    untouched = [0, 2, 4, 5]
    assert not np.asarray(out["kc_pages"])[untouched].any()
    assert not np.asarray(out["stacked"])[:, untouched].any()


def test_concat_snapshots_matches_single_gather():
    pool = _fake_pool()
    s1, _ = gather_pages(pool, [0])
    s2, _ = gather_pages(pool, [2, 3])
    cat = concat_snapshots([s1, s2])
    want, _ = gather_pages(pool, [0, 2, 3])
    np.testing.assert_array_equal(cat["kc_pages"], want["kc_pages"])
    np.testing.assert_array_equal(cat["stacked"], want["stacked"])
    one = concat_snapshots([s1])
    np.testing.assert_array_equal(one["kc_pages"], s1["kc_pages"])


# =============================================================================
# HostSwapStore accounting
# =============================================================================
def _data(nbytes=64):
    return SwapData(pages={"x": np.zeros(nbytes, np.uint8)}, n_pages=1,
                    length=8, key=np.zeros(2, np.uint32), nbytes=nbytes)


def test_swap_store_put_pop_accounting():
    st = HostSwapStore()
    st.put(1, _data(64))
    st.put(2, _data(32))
    assert len(st) == 2 and 1 in st and 3 not in st
    assert st.bytes_out == 96 and st.bytes_in == 0
    assert st.resident_bytes == 96 and st.peak_resident_bytes == 96
    d = st.pop(1)
    assert d.nbytes == 64
    assert st.bytes_in == 64 and st.resident_bytes == 32
    assert st.peak_resident_bytes == 96      # peak is sticky


def test_swap_store_rejects_double_put_and_missing_pop():
    st = HostSwapStore()
    st.put(7, _data())
    with pytest.raises(ValueError, match="already resident"):
        st.put(7, _data())
    with pytest.raises(KeyError, match="not resident"):
        st.pop(8)
    assert len(st) == 1                      # failed ops change nothing


def test_swap_store_reset_keeps_residents():
    """Warmup excision zeroes the traffic counters but a request swapped
    out before the window must still be restorable after it."""
    st = HostSwapStore()
    st.put(1, _data(64))
    st.reset_counters()
    assert st.bytes_out == 0 and st.bytes_in == 0
    assert st.peak_resident_bytes == 64      # re-anchored to residents
    assert st.pop(1).nbytes == 64            # entry survived the reset


# =============================================================================
# BlockManager.swap_out semantics
# =============================================================================
def test_swap_out_snapshots_then_releases():
    bm = BlockManager(8, PAGE, 2, 4)
    assert bm.allocate(0, 2)
    ids = bm.slot_page_ids(0)
    assert bm.map_shared(0, [ids[0]])        # logical row: p0, p1, p0(sh)
    row = bm.swap_out(0)
    assert row == [(ids[0], False), (ids[1], False), (ids[0], True)]
    assert bm.slot_pages(0) == 0
    assert (bm.tables[0] == TRASH_PAGE).all()
    assert bm.page_refcount(ids[0]) == 0     # all refs dropped -> free
    assert bm.free_pages == 7


def test_swap_out_keeps_pinned_and_shared_pages_live():
    bm = BlockManager(8, PAGE, 2, 4)
    assert bm.allocate(0, 2)
    ids = bm.slot_page_ids(0)
    bm.pin(ids[0])                           # trie holds page 0
    assert bm.map_shared(1, [ids[1]])        # another slot reads page 1
    row = bm.swap_out(0)
    assert row == [(ids[0], False), (ids[1], False)]
    assert bm.page_refcount(ids[0]) == 1     # pin outlives the swap-out
    assert bm.page_refcount(ids[1]) == 1     # reader unaffected
    assert bm.slot_page_ids(1) == [ids[1]]
    bm.unpin(ids[0])
    assert bm.page_refcount(ids[0]) == 0
