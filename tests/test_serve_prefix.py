"""Prefix sharing + copy-on-write: token identity and page safety.

The load-bearing guarantee: turning the prefix cache on changes *which*
pages are computed and stored, never the tokens served.  Requests sharing
a common system prompt must produce bit-identical greedy outputs with the
prefix cache on, off, and solo through the contiguous-cache engine — for
every MX element format x both conversion modes, mixed per-role policies,
per-layer policy tables, and the unquantized cache (dense attention).

Under MX this works because a page's quantized KV bytes are a
deterministic function of the token prefix (the trie's dedupe is exact),
and both the suffix prefill and the quantize-aware contiguous prefill
attend the dequantized cache through the same dense kernel.

Also locked down here: the copy-on-write path (fully-cached page-aligned
prompts fork the canonical page instead of writing through it), eviction
safety (reclaiming trie pins never recycles a page another slot still
maps), and the scheduler capacity win (shared prefixes admit more
concurrent requests from the same pool).
"""
import jax
import numpy as np
import pytest

from repro.core.formats import ALL_FORMATS
from repro.models import Model, load_reduced
from repro.models.config import (PolicyTable, QuantPolicy, QuantSpec,
                                 apply_policy_table)
from repro.serve import (BlockManager, ContinuousBatchingEngine,
                         GenerationConfig, PrefixCache, Request, Scheduler,
                         ServeEngine)

MIXED = QuantPolicy.parse("kv_key=int8@32:ocp,kv_value=e2m1@32:ocp")

NEW = 4
PAGE = 8
SLOTS = 3          # < number of requests: waves + slot reuse on path
PREFIX_LEN = 19    # shared system prompt: 2 full pages + a partial
TAILS = [3, 7, 3, 7, 7, 3, 7, 3]   # 2 distinct lengths bounds solo cost


def _prompts(vocab, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=PREFIX_LEN).astype(np.int32)
    return [np.concatenate([prefix, rng.integers(0, vocab, size=t)
                            .astype(np.int32)]) for t in TAILS]


def _serve(cfg, params, prompts, prefix_cache):
    model = Model(cfg)
    eng = ContinuousBatchingEngine(
        model, params, max_slots=SLOTS, page_size=PAGE,
        max_len=max(len(p) for p in prompts) + NEW + 1,
        prefix_cache=prefix_cache)
    rids = [eng.add_request(p, NEW) for p in prompts]
    outs = eng.run()
    return eng, [outs[r] for r in rids]


def _assert_identity(cfg, *, solo=False):
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg.vocab)
    eng_off, off = _serve(cfg, params, prompts, False)
    eng_on, on = _serve(cfg, params, prompts, True)
    for got, ref in zip(on, off):
        np.testing.assert_array_equal(got, ref)
    # sharing actually happened: later waves matched the cached prefix
    # and skipped its full pages
    assert eng_on.prefix.hits > 0
    assert eng_on.prefill_tokens_computed < eng_off.prefill_tokens_computed
    if solo:
        solos = {}
        for p, got in zip(prompts, on):
            n = p.shape[0]
            if n not in solos:
                solos[n] = ServeEngine(model, params, max_len=n + NEW + 2)
            ref = solos[n].generate({"tokens": np.asarray(p)[None, :]},
                                    GenerationConfig(max_new_tokens=NEW))[0]
            np.testing.assert_array_equal(got, ref)
    return eng_on


@pytest.mark.parametrize("mode", ["ocp", "paper"])
@pytest.mark.parametrize("fmt", [f.name for f in ALL_FORMATS])
def test_prefix_matches_off_all_formats(fmt, mode):
    """Prefix-on == prefix-off, all six MX formats x both modes."""
    kv = QuantSpec(fmt, mode)
    cfg = load_reduced("chatglm3_6b",
                       mx=QuantPolicy(kv_key=kv, kv_value=kv))
    _assert_identity(cfg)


def test_prefix_matches_solo_anchor():
    """One cell anchored against solo contiguous serving (the off-engine
    legs of the other cells are tied to solo by test_serve_continuous)."""
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse("kv=int8@32:ocp"))
    _assert_identity(cfg, solo=True)


def test_prefix_matches_off_mixed_roles():
    """INT8 keys + E2M1 bit-packed values share pages exactly."""
    _assert_identity(load_reduced("chatglm3_6b", mx=MIXED))


def test_prefix_matches_off_policy_table():
    """Non-uniform per-layer policies: every layer's pool dedupes on the
    same trie chain."""
    table = PolicyTable("kv=int8@32:ocp",
                        {1: "kv_key=e2m1@32:ocp,kv_value=e4m3@32:ocp"})
    _assert_identity(apply_policy_table(load_reduced("chatglm3_6b"), table))


def test_prefix_matches_off_fp_cache():
    """Unquantized pages (dense attention): fp cache round-trips exactly,
    so prefix sharing is bit-safe there too."""
    _assert_identity(load_reduced("chatglm3_6b"), solo=True)


# =============================================================================
# copy-on-write
# =============================================================================
def test_cow_forks_fire_and_stay_identical():
    """Fully-cached page-aligned prompts take the COW path: the engine
    forks the last shared page before recomputing the final position into
    it, and the served tokens still match the prefix-off engine."""
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse("kv=int8@32:ocp"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    base = rng.integers(0, cfg.vocab, size=2 * PAGE).astype(np.int32)
    # 6 identical page-aligned prompts + 1 longer: dedupe, COW, suffix
    prompts = [base.copy() for _ in range(6)] + \
        [np.concatenate([base,
                         rng.integers(0, cfg.vocab, size=3).astype(np.int32)])]
    eng_off, off = _serve(cfg, params, prompts, False)
    eng_on, on = _serve(cfg, params, prompts, True)
    for got, ref in zip(on, off):
        np.testing.assert_array_equal(got, ref)
    assert eng_on.n_cow_forks > 0
    assert eng_on.prefix.hits > 0
    # every fully-cached admission recomputed exactly one position
    assert eng_on.prefill_tokens_computed < eng_off.prefill_tokens_computed
    assert eng_off.n_cow_forks == 0


# =============================================================================
# eviction safety: decref'd shared pages never recycle under a reader
# =============================================================================
def test_reclaim_never_recycles_mapped_pages():
    """Dropping a trie pin while another slot still maps the page must not
    return it to the free list; allocation can never hand it out again."""
    bm = BlockManager(num_pages=8, page_size=4, max_slots=2,
                      max_pages_per_slot=4)
    pc = PrefixCache(bm)
    tokens = np.arange(8, dtype=np.int32)         # 2 full pages
    assert bm.allocate(0, 2)
    ids = bm.slot_page_ids(0)
    assert pc.insert(tokens, ids) == 2
    bm.release(0)                                  # writer evicted: pinned
    pages, matched = pc.lookup(tokens)
    assert pages == ids and matched == 8
    assert bm.map_shared(1, pages)                 # reader slot maps them
    # pressure: reclaim wants 2 pages, but the trie's leaves are still
    # table-mapped -> unpinning them frees nothing
    assert pc.reclaim(2) == 0
    assert pc.pinned_pages == 0                    # pins are gone...
    assert all(bm.page_refcount(p) == 1 for p in ids)   # ...pages live
    # fresh allocations must not alias the reader's mapping
    assert bm.allocate(0, min(bm.free_pages, 4))
    assert not set(bm.slot_page_ids(0)) & set(ids)
    bm.release(1)                                  # last reader frees them
    assert all(bm.page_refcount(p) == 0 for p in ids)


def test_engine_eviction_mid_window_keeps_identity():
    """Requests finishing at different steps while sharing pinned pages:
    evictions decref mid-run and outputs still match prefix-off."""
    cfg = load_reduced("chatglm3_6b", mx=QuantPolicy.parse("kv=e4m3@32:ocp"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prefix = rng.integers(0, cfg.vocab, size=2 * PAGE).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(0, cfg.vocab, size=t)
                               .astype(np.int32)]) for t in [1, 5, 9, 1, 5, 9]]
    budgets = [2, 6, 3, 5, 2, 4]                   # staggered finishes

    def run(pc):
        eng = ContinuousBatchingEngine(
            model, params, max_slots=SLOTS, page_size=PAGE,
            max_len=max(len(p) for p in prompts) + max(budgets) + 1,
            prefix_cache=pc)
        rids = [eng.add_request(p, b) for p, b in zip(prompts, budgets)]
        outs = eng.run()
        return eng, [outs[r] for r in rids]

    eng_off, off = run(False)
    eng_on, on = run(True)
    for got, ref in zip(on, off):
        np.testing.assert_array_equal(got, ref)
    assert eng_on.prefix.hits > 0


# =============================================================================
# scheduler capacity: shared prefixes admit more from the same pool
# =============================================================================
def _submit(sch, rids, prompt_len, new=NEW, vocab=1000, seed=3):
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in rids:
        p = rng.integers(0, vocab, size=prompt_len).astype(np.int32)
        reqs.append(Request(rid=rid, prompt=p, max_new_tokens=new))
        sch.submit(reqs[-1])
    return reqs


def test_admission_capacity_improves_with_shared_prefix():
    """Same pool, same prompts: a warmed prefix cache turns per-request
    page demand from 3 private pages into 1, so admission goes from two
    concurrent requests to a full house."""
    prefix = np.arange(16, dtype=np.int32)         # 2 full pages

    def mk(with_prefix):
        bm = BlockManager(num_pages=8, page_size=8, max_slots=4,
                          max_pages_per_slot=3)
        pc = PrefixCache(bm) if with_prefix else None
        return bm, pc, Scheduler(max_slots=4, blocks=bm, prefix=pc)

    def traffic(sch, seed):
        rng = np.random.default_rng(seed)
        out = []
        for rid in range(4):
            p = np.concatenate(
                [prefix, rng.integers(0, 1000, size=1).astype(np.int32)])
            out.append(Request(rid=rid, prompt=p, max_new_tokens=NEW))
            sch.submit(out[-1])
        return out

    # --- warmed prefix cache ---------------------------------------------
    bm, pc, sch = mk(True)
    warm = Request(rid=99, prompt=prefix.copy(), max_new_tokens=1)
    sch.submit(warm)
    assert sch.admit() == [warm]
    pc.insert(warm.prompt, bm.slot_page_ids(warm.slot)[:2])
    sch.evict(warm)                                # pages survive via pins
    admitted = sch.admit()                         # nothing waiting yet
    traffic(sch, seed=4)
    admitted = sch.admit()
    assert len(admitted) == 4                      # full house
    assert all(r.matched_tokens == 16 for r in admitted)
    assert bm.shared_pages == 2                    # one canonical chain
    # --- no prefix cache: 3 private pages each, so the same pool (5 free
    # after the warm chain's 2 stay pinned there, 7 here) fits only 2 ----
    bm2, _, sch2 = mk(False)
    traffic(sch2, seed=4)
    admitted2 = sch2.admit()
    assert len(admitted2) == 2
    assert bm2.shared_pages == 0


def test_scheduler_backs_out_partial_admission():
    """When the pool can't cover a hit's private suffix even after
    reclaim, admission must back out the tentative shared mapping."""
    bm = BlockManager(num_pages=5, page_size=8, max_slots=2,
                      max_pages_per_slot=4)
    pc = PrefixCache(bm)
    sch = Scheduler(max_slots=2, blocks=bm, prefix=pc)
    warm = Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                   max_new_tokens=8)
    sch.submit(warm)
    assert sch.admit() == [warm]
    pc.insert(warm.prompt, bm.slot_page_ids(warm.slot)[:1])
    # warm keeps running (1 page mapped + 1 growth reserve): 2 of the 3
    # remaining pages are spendable.  The hit matches 1 shared page but
    # its private suffix needs 3 more; reclaim can't help (the chain is
    # still table-mapped, unpinning frees nothing) -> back out.
    big = Request(rid=1, prompt=np.concatenate(
        [np.arange(8), np.arange(9)]).astype(np.int32), max_new_tokens=8)
    sch.submit(big)
    assert sch.admit() == []
    assert big.slot == -1
    # the backed-out mapping left no refcounts behind (the pin was spent
    # by the failed reclaim; warm's own table ref remains)
    assert bm.page_refcount(bm.slot_page_ids(warm.slot)[0]) == 1
    assert bm.mapped_pages == bm.slot_pages(warm.slot)
    assert pc.pinned_pages == 0
