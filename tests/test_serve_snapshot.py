"""Engine snapshot/restore: token-identical rewind of a live engine.

``serve.snapshot.capture`` freezes a running engine — page pool (host
copy), block tables + free list, scheduler queues and per-request
generation state, swap store, prefix trie, and the engine's host mirrors
— and ``restore`` rewinds the same engine to that instant.  The contract
under test: finishing a workload *after* a restore yields exactly the
tokens of an uninterrupted run, regardless of how far past the snapshot
the engine had advanced, including under temperature sampling (per-slot
PRNG keys are part of the capture) and with the prefix cache warm (the
trie is rebuilt with its pins riding the restored block tables).

This is the mechanism behind the front end's watchdog recovery
(``tests/test_serve_faults.py`` covers the async path); here the sync
engine is exercised directly so failures localize.
"""
import jax
import numpy as np
import pytest

from repro.models import Model, load_reduced
from repro.models.config import QuantPolicy
from repro.serve import (ContinuousBatchingEngine, GenerationConfig,
                         capture, restore)

MIXED = QuantPolicy.parse("kv_key=int8@32:paper,kv_value=e4m3@32:paper")
PAGE = 8
NEW = 10


@pytest.fixture(scope="module")
def mixed():
    cfg = load_reduced("chatglm3_6b", mx=MIXED)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
            for n in (7, 12, 9)]


def _engine(model, params, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("gen", GenerationConfig(max_new_tokens=NEW))
    return ContinuousBatchingEngine(model, params, page_size=PAGE,
                                    max_len=40, sync_every=4, **kw)


def _clean(mixed, **kw):
    cfg, model, params = mixed
    eng = _engine(model, params, **kw)
    rids = [eng.add_request(p, NEW) for p in _prompts(cfg)]
    return rids, eng.run()


# =============================================================================
# token identity across capture -> advance -> restore -> finish
# =============================================================================
@pytest.mark.parametrize("steps_past", [0, 2])
def test_restore_mid_stream_token_identical(mixed, steps_past):
    cfg, model, params = mixed
    rids, want = _clean(mixed)

    eng = _engine(model, params)
    for p in _prompts(cfg):
        eng.add_request(p, NEW)
    eng.step()                            # requests are mid-generation
    snap = capture(eng)
    assert snap.nbytes > 0
    for _ in range(steps_past):           # advance past the snapshot...
        eng.step()
    restore(eng, snap)                    # ...and rewind
    out = eng.run()
    assert set(out) == set(rids)
    for r in rids:
        np.testing.assert_array_equal(out[r], want[r])


def test_restore_after_completion_replays_identically(mixed):
    """Even a fully finished engine rewinds: the finished list is
    truncated to the snapshot's length and the replay re-finishes every
    in-flight request with the same tokens."""
    cfg, model, params = mixed
    rids, want = _clean(mixed)
    eng = _engine(model, params)
    for p in _prompts(cfg):
        eng.add_request(p, NEW)
    eng.step()
    snap = capture(eng)
    first = eng.run()
    restore(eng, snap)
    assert not eng.scheduler.finished     # rewound before any finish
    again = eng.run()
    for r in rids:
        np.testing.assert_array_equal(first[r], want[r])
        np.testing.assert_array_equal(again[r], want[r])


def test_restore_sampled_keys_token_identical(mixed):
    """temperature > 0: per-slot PRNG keys are captured, so the restored
    continuation samples the same tokens."""
    cfg, model, params = mixed
    gen = GenerationConfig(max_new_tokens=NEW, temperature=0.7)
    eng = _engine(model, params, gen=gen)
    rids = [eng.add_request(p, NEW) for p in _prompts(cfg)]
    eng.step()
    snap = capture(eng)
    want = eng.run()
    restore(eng, snap)
    out = eng.run()
    for r in rids:
        np.testing.assert_array_equal(out[r], want[r])


def test_snapshot_is_an_isolated_host_copy(mixed):
    """Stepping the engine after capture must not leak into the
    snapshot: the captured per-request state and pool stay frozen."""
    cfg, model, params = mixed
    eng = _engine(model, params)
    rids = [eng.add_request(p, NEW) for p in _prompts(cfg)]
    eng.step()
    snap = capture(eng)
    out_lens = {r.rid: len(r.out) for r in eng.scheduler.running.values()}
    lengths = snap.engine["lengths"].copy()
    eng.step()                            # engine advances...
    # ...but the captured state is frozen at the earlier instant
    for req, fields in snap.requests:
        assert len(fields["out"]) == out_lens[req.rid]
        assert len(req.out) > len(fields["out"])
    np.testing.assert_array_equal(snap.engine["lengths"], lengths)
    assert all(isinstance(leaf, np.ndarray) for leaf in
               jax.tree_util.tree_leaves(snap.pool))
    restore(eng, snap)
    out = eng.run()
    assert set(out) == set(rids)


def test_restore_with_prefix_cache_keeps_trie_serving(mixed):
    """Capture with a warm trie; after restore the trie still matches
    (pins ride the restored block tables — no double-pinning)."""
    cfg, model, params = mixed
    rng = np.random.default_rng(11)
    warm = rng.integers(1, cfg.vocab, size=PAGE).astype(np.int32)
    eng = _engine(model, params, prefix_cache=True)
    eng.add_request(warm, 1)
    eng.run()
    hits0 = eng.prefix.hits
    snap = capture(eng)

    tail = rng.integers(1, cfg.vocab, size=5).astype(np.int32)
    eng.add_request(np.concatenate([warm, tail]), 3)
    want = eng.run()
    assert eng.prefix.hits == hits0 + 1

    restore(eng, snap)
    assert eng.prefix.hits == hits0
    rid2 = eng.add_request(np.concatenate([warm, tail]), 3)
    out = eng.run()
    assert eng.prefix.hits == hits0 + 1   # trie still serves post-restore
    np.testing.assert_array_equal(out[rid2], want[min(want)])


def test_restore_preserves_swapped_out_requests(mixed):
    """A request resident in the host swap store at capture time is
    restorable after the rewind (store entries are part of the
    snapshot)."""
    cfg, model, params = mixed

    eng = _engine(model, params, max_slots=2, preempt=True)
    rng = np.random.default_rng(3)
    victim = eng.add_request(
        rng.integers(1, cfg.vocab, size=9).astype(np.int32), 12,
        priority=5)
    eng.step()
    others = [eng.add_request(
        rng.integers(1, cfg.vocab, size=17).astype(np.int32), 6,
        priority=0) for _ in range(2)]
    for _ in range(20):
        if victim in eng.swap_store:
            break
        eng.step()
    assert victim in eng.swap_store       # preempted and resident
    snap = capture(eng)
    # run() reports only requests finishing after its call — an "other"
    # that completed during the step loop above is in neither dict, so
    # compare on want's keys (the victim must be among them: it still
    # owes tokens from the swap store)
    want = eng.run()
    assert victim in want
    restore(eng, snap)
    assert victim in eng.swap_store       # entry survived the rewind
    out = eng.run()
    assert set(out) == set(want)
    for r in want:
        np.testing.assert_array_equal(out[r], want[r])
