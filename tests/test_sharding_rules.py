"""Unit tests for the logical sharding-rule engine (no mesh needed)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (current_rules, logical, make_rules,
                                 param_specs, use_rules,
                                 weight_gather_enabled)


def test_make_rules_single_pod():
    r = make_rules(("data", "model"))
    assert r["batch"] == ("data",)
    assert r["model"] == ("model",)
    assert r["wgather"] is not None


def test_make_rules_multi_pod_decode():
    r = make_rules(("pod", "data", "model"), fsdp_params=False,
                   seq_sharded=True)
    assert r["batch"] == ("pod", "data")
    assert r["seq"] == ("model",)
    assert r["wgather"] is None


def test_logical_noop_without_rules():
    x = jnp.ones((4, 4))
    assert current_rules() is None
    y = logical(x, "batch", "model")
    assert y is x  # identity, no constraint applied


def test_weight_gather_toggle():
    with use_rules(make_rules(("data", "model"), fsdp_params=False)):
        assert not weight_gather_enabled()
    with use_rules(make_rules(("data", "model"), fsdp_params=True)):
        assert weight_gather_enabled()
    assert not weight_gather_enabled()


def test_param_specs_shapes():
    params = {
        "embed": jnp.zeros((1024, 64)),
        "layers": {
            "attn": {"wq": jnp.zeros((4, 64, 128)),
                     "wo": jnp.zeros((4, 128, 64))},
            "moe": {"experts": {"w1": jnp.zeros((4, 8, 64, 32))}},
            "ln1": jnp.zeros((4, 64)),
        },
        "lm_head": jnp.zeros((64, 1024)),
    }
    specs = param_specs(params)
    assert specs["embed"] == P("model", "data")
    # stacked layer weights: leading scan dim unsharded
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", "data")
    assert specs["layers"]["moe"]["experts"]["w1"] == \
        P(None, "model", "data", None)
    # rank-1 (after scan dim): replicated
    assert specs["layers"]["ln1"] == P(None, None)
    # lm_head: default col-parallel (not the embed rule)
    assert specs["lm_head"] == P("data", "model")


def test_validated_divisibility():
    from repro.launch.cells import _validated
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = _validated(P("data", "model"), (50, 64), FakeMesh())
    assert spec == P(None, "model")   # 50 % 16 != 0 -> dropped
    spec = _validated(P(("pod", "data"), None), (64, 3), FakeMesh())
    assert spec[1] is None
