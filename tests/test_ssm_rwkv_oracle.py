"""Chunked Mamba2-SSD / RWKV6 implementations vs naive step-by-step
recurrence oracles."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.rwkv import _wkv_chunked
from repro.models.ssm import _ssd_chunked


def test_ssd_chunked_matches_recurrence():
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 48, 3, 8, 4
    xh = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    la = jnp.asarray(-rng.uniform(0.01, 1.5, size=(b, s, h))
                     .astype(np.float32))
    y, final = _ssd_chunked(xh, bm, cm, la, chunk=16)
    # oracle: S_t = a_t S_{t-1} + x_t (x) B_t ; y_t = C_t . S_t
    st = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    xh_, bm_, cm_, la_ = (np.asarray(t, np.float64)
                          for t in (xh, bm, cm, la))
    for t in range(s):
        a = np.exp(la_[:, t])                        # (b,h)
        st = st * a[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", xh_[:, t], bm_[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", cm_[:, t], st)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), st, rtol=2e-4, atol=2e-4)


def test_wkv_chunked_matches_recurrence():
    rng = np.random.default_rng(1)
    b, s, h, d = 2, 48, 2, 8   # s must divide by chunk (padding is caller's)
    r = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    lw = jnp.asarray(-rng.uniform(0.01, 3.0, size=(b, s, h, d))
                     .astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    y, final = _wkv_chunked(r, k, v, lw, u, chunk=16)
    # oracle: o_t = r_t (S_{t-1} + diag(u) k_t v_t^T); S_t = diag(w)S + k v^T
    st = np.zeros((b, h, d, d), np.float64)
    ys = np.zeros((b, s, h, d), np.float64)
    r_, k_, v_, lw_, u_ = (np.asarray(t, np.float64)
                           for t in (r, k, v, lw, u))
    for t in range(s):
        kv = np.einsum("bhd,bhe->bhde", k_[:, t], v_[:, t])
        ys[:, t] = np.einsum("bhd,bhde->bhe", r_[:, t],
                             st + u_[None, :, :, None] * kv)
        st = st * np.exp(lw_[:, t])[..., None] + kv
    np.testing.assert_allclose(np.asarray(y), ys, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(final), st, rtol=3e-4, atol=3e-4)


def test_ssd_chunk_size_invariance():
    rng = np.random.default_rng(2)
    b, s, h, p, n = 1, 64, 2, 4, 4
    xh = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
    bm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    cm = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
    la = jnp.asarray(-rng.uniform(0.01, 1.0, size=(b, s, h))
                     .astype(np.float32))
    y1, f1 = _ssd_chunked(xh, bm, cm, la, chunk=8)
    y2, f2 = _ssd_chunked(xh, bm, cm, la, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=2e-4,
                               atol=2e-4)


def test_wkv_chunk_size_invariance():
    rng = np.random.default_rng(3)
    b, s, h, d = 1, 64, 2, 8
    r, k, v = (jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
               for _ in range(3))
    lw = jnp.asarray(-rng.uniform(0.01, 2.0, size=(b, s, h, d))
                     .astype(np.float32))
    u = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    y1, f1 = _wkv_chunked(r, k, v, lw, u, chunk=8)
    y2, f2 = _wkv_chunked(r, k, v, lw, u, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-4,
                               atol=3e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=3e-4,
                               atol=3e-4)
