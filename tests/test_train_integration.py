"""Training integration: loss decreases on a tiny LM, with and without the
MX converter in the loop; checkpoint/resume is bit-identical."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, SyntheticLM, make_batch_for
from repro.models import Model, load_reduced
from repro.models.config import MXPolicy
from repro.optim import AdamWConfig
from repro.train import (LoopConfig, build_train_step, init_train_state,
                         train_loop)

B, S, STEPS = 8, 32, 25


def _setup(arch="chatglm3_6b", mx=None, microbatches=1):
    over = {"remat": False}
    if mx is not None:
        over["mx"] = mx
    cfg = load_reduced(arch, **over)
    model = Model(cfg)
    params, opt_state = init_train_state(model, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=STEPS,
                          weight_decay=0.0)
    step = jax.jit(build_train_step(
        model, opt_cfg, microbatches=microbatches,
        fake_quant=mx is not None and mx.weights))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=S,
                                  global_batch=B, seed=3))
    return cfg, model, params, opt_state, step, data


def _run(cfg, params, opt_state, step, data, n=STEPS):
    losses = []
    for i in range(n):
        batch = make_batch_for(cfg, data.batch(i))
        params, opt_state, metrics = step(params, opt_state, batch,
                                          jnp.asarray(i))
        losses.append(float(metrics["loss"]))
    return losses, params, opt_state


def test_loss_decreases_baseline():
    cfg, model, params, opt, step, data = _setup()
    losses, *_ = _run(cfg, params, opt, step, data)
    assert losses[-1] < losses[0] * 0.8, losses[:3] + losses[-3:]
    assert np.isfinite(losses).all()


def test_loss_decreases_with_mx_weights():
    """MX (paper-mode E4M3) fake-quantized weights still train."""
    mx = MXPolicy(fmt="e4m3", mode="paper", weights=True)
    cfg, model, params, opt, step, data = _setup(mx=mx)
    losses, *_ = _run(cfg, params, opt, step, data)
    assert losses[-1] < losses[0] * 0.85, losses[:3] + losses[-3:]
    assert np.isfinite(losses).all()


def test_microbatched_matches_full_batch():
    cfg, model, p1, o1, step1, data = _setup(microbatches=1)
    _, _, p2, o2, step2, _ = _setup(microbatches=4)
    b = make_batch_for(cfg, data.batch(0))
    p1n, o1n, m1 = step1(p1, o1, b, jnp.asarray(0))
    p2n, o2n, m2 = step2(p2, o2, b, jnp.asarray(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    for a, c in zip(jax.tree_util.tree_leaves(p1n),
                    jax.tree_util.tree_leaves(p2n)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   rtol=2e-3, atol=2e-5)


def test_checkpoint_resume_bitexact(tmp_path):
    """Crash/restart at step 10 must reproduce the uninterrupted run."""
    ck = str(tmp_path / "ckpt")
    cfg, model, params, opt, step, data = _setup()

    def batch_fn(i):
        return make_batch_for(cfg, data.batch(i))

    # uninterrupted run to 20
    out_a = train_loop(LoopConfig(total_steps=20, ckpt_dir=str(tmp_path /
                                                               "a"),
                                  ckpt_every=0, log_every=1000),
                       step, params, opt, batch_fn, log=lambda *_: None)
    # interrupted: run to 10 w/ checkpoint, then "restart" and run to 20
    out_b1 = train_loop(LoopConfig(total_steps=10, ckpt_dir=ck,
                                   ckpt_every=10, log_every=1000),
                        step, params, opt, batch_fn, log=lambda *_: None)
    out_b2 = train_loop(LoopConfig(total_steps=20, ckpt_dir=ck,
                                   ckpt_every=10, log_every=1000),
                        step, params, opt, batch_fn, log=lambda *_: None)
    for a, b in zip(jax.tree_util.tree_leaves(out_a["params"]),
                    jax.tree_util.tree_leaves(out_b2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir never shadows a valid checkpoint."""
    from repro.ckpt import latest_step, save_atomic
    d = str(tmp_path)
    save_atomic(d, 5, {"x": jnp.ones((3,))})
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert latest_step(d) == 5
