"""Weight-resident serving: MXWeight storage, fused-kernel dispatch,
per-layer policy tables, and engine-level token identity + HBM accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ALL_FORMATS, MXWeight, QuantSpec, mx_dequantize,
                        mx_quantize, mx_weight_nbytes, pack_codes_rows,
                        params_nbytes, unpack_codes_rows)
from repro.kernels.backend import MATMUL_ENV_VAR, resolve_matmul_impl
from repro.kernels.ops import mx_matmul_resident
from repro.models import (Model, PolicyTable, QuantPolicy,
                          apply_policy_table, load_reduced)
from repro.models import decoder, layers as L
from repro.serve import ContinuousBatchingEngine, GenerationConfig

ALL_FMTS = [f.name for f in ALL_FORMATS]
SUB_BYTE = [(f.name, f.code_bits) for f in ALL_FORMATS if f.code_bits < 8]


# ------------------------------------------------------------- row packing
@pytest.mark.parametrize("fmt,bits", SUB_BYTE)
def test_pack_codes_rows_roundtrip(fmt, bits):
    rng = np.random.default_rng(0)
    for lead in [(), (3,)]:
        k, n = 96, 5
        c = jnp.asarray(rng.integers(0, 2 ** bits, size=lead + (k, n)),
                        jnp.uint8)
        p = pack_codes_rows(c, fmt)
        assert p.shape[:-2] == lead and p.shape[-1] == n
        assert p.shape[-2] == (k // 2 if bits == 4 else k // 4 * 3)
        back = unpack_codes_rows(p, fmt, k)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(c))


# --------------------------------------------------------------- container
@pytest.mark.parametrize("fmt", ALL_FMTS)
def test_mxweight_dequantize_matches_mx_quantize(fmt):
    """MXWeight.quantize (bit-packed storage) must round-trip to the exact
    same f32 weight as the plain mx_quantize/mx_dequantize pipeline — for
    2-D weights and for stacked 3-D MoE expert weights (take(i))."""
    rng = np.random.default_rng(1)
    spec = QuantSpec(fmt, "ocp", 32, True)
    w = jnp.asarray(rng.normal(size=(4, 64, 24)).astype(np.float32) * 0.1)
    mw = MXWeight.quantize(w, spec)
    ref = mx_dequantize(mx_quantize(w, QuantSpec(fmt, "ocp", 32, False),
                                    axis=1))
    np.testing.assert_array_equal(np.asarray(mw.dequantize()),
                                  np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(mw.take(2).dequantize()),
                                  np.asarray(ref[2]))
    # storage accounting: per-matrix analytic bytes times the expert dim
    assert mw.nbytes == 4 * mx_weight_nbytes(64, 24, spec)
    assert mw.packed == (spec.format.code_bits < 8)


def test_packed_e2m1_bits_per_weight():
    spec = QuantSpec("e2m1", "ocp", 32, True)
    k, n = 256, 64
    assert mx_weight_nbytes(k, n, spec) * 8 / (k * n) == 4.25
    rng = np.random.default_rng(2)
    mw = MXWeight.quantize(
        jnp.asarray(rng.normal(size=(k, n)).astype(np.float32)), spec)
    assert mw.nbytes == mx_weight_nbytes(k, n, spec)


# ------------------------------------------------------- fused vs fallback
@pytest.mark.parametrize("fmt", ALL_FMTS)
@pytest.mark.parametrize("mode", ["paper", "ocp"])
def test_resident_fused_bitwise_matches_einsum(fmt, mode):
    """At single-k-tile shapes the fused kernel and the dequant-einsum
    fallback contract in the same order: outputs must be bit-identical,
    for both packed and unpacked storage."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(5, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 40)).astype(np.float32) * 0.05)
    for packed in (True, False):
        mw = MXWeight.quantize(w, QuantSpec(fmt, mode, 32, packed))
        o_fused = mx_matmul_resident(a, mw, "fused")
        o_einsum = mx_matmul_resident(a, mw, "einsum")
        np.testing.assert_array_equal(np.asarray(o_fused),
                                      np.asarray(o_einsum))


def test_dense_dispatch_env_var(monkeypatch):
    monkeypatch.delenv(MATMUL_ENV_VAR, raising=False)
    assert resolve_matmul_impl() == "fused"
    monkeypatch.setenv(MATMUL_ENV_VAR, "einsum")
    assert resolve_matmul_impl() == "einsum"
    assert resolve_matmul_impl("fused") == "fused"   # explicit beats env
    with pytest.raises(ValueError, match="einsum"):
        resolve_matmul_impl("nope")

    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
    mw = MXWeight.quantize(
        jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32)),
        "e4m3@32:ocp")
    y_einsum = L.dense(x, mw)                         # env says einsum
    monkeypatch.setenv(MATMUL_ENV_VAR, "fused")
    y_fused = L.dense(x, mw)
    np.testing.assert_array_equal(np.asarray(y_fused),
                                  np.asarray(y_einsum))


# ----------------------------------------------------------- policy tables
def test_policy_table_mixed_layer_quantization():
    """A non-uniform table quantizes each layer per its own spec: layer 0
    e4m3, layer 1 e2m1 (bit-packed), and a no-weights override stays fp."""
    table = PolicyTable(
        default=QuantPolicy.parse("weights=e4m3@32:ocp"),
        overrides=((1, QuantPolicy.parse("weights=e2m1@32:ocp")),))
    cfg = apply_policy_table(load_reduced("chatglm3_6b"), table)
    assert cfg.mx_table is not None
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qp = model.quantize_weights(params)
    layers = qp["layers"]
    assert isinstance(layers, list) and len(layers) == cfg.n_layers
    assert layers[0]["attn"]["wq"].fmt == "e4m3"
    assert not layers[0]["attn"]["wq"].packed
    assert layers[1]["attn"]["wq"].fmt == "e2m1"
    assert layers[1]["attn"]["wq"].packed

    # forward runs through the unrolled per-layer walk, and the env-var
    # impl flip is invisible in the outputs (single-k-tile bit identity)
    tok = jnp.asarray(np.arange(8, dtype=np.int32)[None, :] % cfg.vocab)
    logits_f, _ = decoder.forward(qp, tok, cfg)
    assert np.isfinite(np.asarray(logits_f)).all()

    fp_table = PolicyTable(default=table.default,
                           overrides=((1, QuantPolicy()),))
    cfg_fp = apply_policy_table(load_reduced("chatglm3_6b"), fp_table)
    qp2 = Model(cfg_fp).quantize_weights(params)
    assert isinstance(qp2["layers"][0]["attn"]["wq"], MXWeight)
    assert isinstance(qp2["layers"][1]["attn"]["wq"], jax.Array)


# ------------------------------------------------- engine-level end-to-end
def test_engine_token_identity_and_weight_pool():
    """Weight-resident serving must emit the same tokens as serving the
    materialized (dequantized) weights, with a strictly smaller weight
    pool whose size matches the params_nbytes accounting."""
    cfg = load_reduced("chatglm3_6b",
                       mx=QuantPolicy.parse("weights=e4m3@32:ocp"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qparams = model.quantize_weights(params)
    is_mx = lambda l: isinstance(l, MXWeight)                 # noqa: E731
    refparams = jax.tree_util.tree_map(
        lambda l: l.dequantize() if is_mx(l) else l, qparams, is_leaf=is_mx)

    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (4, 7)]
    gen = GenerationConfig(max_new_tokens=4)
    outs = {}
    for name, p in [("resident", qparams), ("materialized", refparams)]:
        eng = ContinuousBatchingEngine(model, p, max_slots=2, page_size=8,
                                       max_len=16, gen=gen)
        for pr in prompts:
            eng.add_request(pr, 4)
        outs[name] = (eng.run(), eng.weight_pool_nbytes)
    toks_q, bytes_q = outs["resident"]
    toks_f, bytes_f = outs["materialized"]
    for r in toks_q:
        np.testing.assert_array_equal(toks_q[r], toks_f[r])
    assert bytes_q == params_nbytes(qparams)
    assert bytes_q < bytes_f
    # every quantized leaf matches the analytic spec.storage_nbytes bytes
    n_mx = 0
    for leaf in jax.tree_util.tree_leaves(qparams, is_leaf=is_mx):
        if is_mx(leaf):
            lead = int(np.prod(leaf.codes.shape[:-2], dtype=np.int64))
            assert leaf.nbytes == lead * mx_weight_nbytes(leaf.k, leaf.n,
                                                          leaf.spec)
            n_mx += 1
    assert n_mx > 0
